"""Device renewal engine tests: the fused scan vs the float64 host oracle.

The device engine (``sweep.renewal_compose_device`` /
``renewal_monte_carlo_device``) re-implements the whole-run renewal
composition as one jitted scan over epochs x runs x scenarios.  Its
contract is the host oracle: identical decisions, occurrence/truncation
semantics, and whole-run energies within 1e-4 relative (the acceptance
bar; the engine is traced under x64 so observed agreement is ~1e-12).
The fold form of Algorithm 1 it dispatches is pinned *bit-exactly* to the
vectorized ``evaluate_strategies``.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import energy_model as em
from repro.core import failures as F
from repro.core import strategies, sweep
from repro.core.scenarios import paper_scenarios
from repro.core.simulator import simulate_run

GAPS = np.array([5000.0, 9000.0, 4000.0, 2500.0])
MAKESPAN = 60000.0

SCENARIOS = sorted(paper_scenarios())


def _nonexp_processes():
    """The non-exponential processes pinned across engines (the exponential
    is covered by every pre-existing test in this file)."""
    return [
        F.Weibull.from_mtbf(0.7, 12000.0),
        F.EmpiricalTrace(
            np.random.default_rng(3).weibull(0.8, 400) * 15000.0),
    ]


def _device_slice(res, s):
    """Scenario ``s`` of a stacked device result, as numpy (``gaps`` is
    shared across scenarios and stays whole)."""
    fields = {
        f: jax.tree.map(lambda a: np.asarray(a)[s], getattr(res, f))
        for f in res.__dataclass_fields__ if f != "gaps"
    }
    return sweep.RenewalDeviceResult(gaps=np.asarray(res.gaps), **fields)


# ---------------------------------------------------------------------------
# cross-validation: device scan == host float64 oracle, pointwise
# ---------------------------------------------------------------------------

def test_device_compose_matches_host_oracle_pointwise():
    """All six Table-4 scenarios in one dispatch: per-epoch energies,
    decisions, and whole-run totals match the host oracle (bar 1e-4; the
    x64-traced scan agrees to ~1e-12)."""
    cfgs = [paper_scenarios()[n] for n in SCENARIOS]
    dev = sweep.renewal_compose_device(cfgs, GAPS, MAKESPAN)
    for s, cfg in enumerate(cfgs):
        host = sweep.renewal_compose(cfg, GAPS, MAKESPAN)
        d = _device_slice(dev, s)
        np.testing.assert_array_equal(d.valid[0], host.valid[0], err_msg=cfg.name)
        assert int(d.n_failures[0]) == int(host.n_failures[0])
        assert bool(d.truncated[0]) == bool(host.truncated[0])
        k = host.valid[0]
        np.testing.assert_array_equal(
            np.asarray(d.decision.level)[0][k],
            np.asarray(host.decision.level)[0][k], err_msg=cfg.name)
        np.testing.assert_array_equal(
            np.asarray(d.decision.wait_action)[0][k],
            np.asarray(host.decision.wait_action)[0][k], err_msg=cfg.name)
        for field in ("epoch_ref", "epoch_int", "epoch_failed"):
            np.testing.assert_allclose(
                getattr(d, field)[0], getattr(host, field)[0],
                rtol=1e-4, atol=1e-6, err_msg=f"{cfg.name} {field}")
        for field in ("balanced_energy", "energy_ref", "energy_int",
                      "end_time", "t_renewal", "t_fail"):
            np.testing.assert_allclose(
                getattr(d, field)[0], getattr(host, field)[0],
                rtol=1e-4, err_msg=f"{cfg.name} {field}")
        denom = max(abs(float(host.saving[0])), 1e-4 * float(host.energy_ref[0]))
        assert abs(float(d.saving[0]) - float(host.saving[0])) / denom < 1e-4


def test_device_first_epoch_equals_single_failure_sweep():
    """Epoch 0 of a device renewal run reproduces the single-failure sweep
    at that offset — the device engine strictly generalizes PR 1's grid."""
    cfg = paper_scenarios()["scenario2_long_reexec"]
    delta = 4321.0
    res = sweep.renewal_compose_device(cfg, np.array([delta, 1e9]), 1e7)
    single = sweep.sweep_failure_times(cfg, np.array([delta]))
    np.testing.assert_array_equal(
        np.asarray(res.decision.level)[0, 0, 0],
        np.asarray(single.decision.level)[0])
    np.testing.assert_allclose(
        np.asarray(res.decision.saving)[0, 0, 0],
        np.asarray(single.decision.saving)[0], rtol=1e-6)


# ---------------------------------------------------------------------------
# the fold form of Algorithm 1 is bit-identical to the vectorized form
# ---------------------------------------------------------------------------

def test_fold_matches_vectorized_evaluate_strategies():
    """Every Decision field of evaluate_strategies_fold matches the
    vectorized engine — discrete fields exactly, energies to XLA
    FMA-contraction round-off (~1 ulp) — including infeasible fallbacks,
    idle-wait configs, and sleep-gate boundaries."""
    cfg = paper_scenarios()["scenario1_short_reexec"]
    inp = sweep.sweep_inputs(cfg)
    rng = np.random.default_rng(7)
    shape = (64, 3)
    t_comp = rng.uniform(5.0, 4000.0, shape).astype(np.float32)
    # include infeasible points (t_failed < even the fa comp phase)
    t_failed = np.where(
        rng.uniform(size=shape) < 0.15,
        rng.uniform(1.0, 50.0, shape),
        t_comp + rng.uniform(0.0, 4000.0, shape),
    ).astype(np.float32)
    n_ckpt = rng.integers(0, 4, shape + (4,)).astype(np.float32)
    wait_mode = rng.integers(0, 2, shape).astype(np.int32)

    ref = strategies.evaluate_strategies(
        t_comp, t_failed, n_ckpt, inp.dur, inp.ladder, inp.sleep,
        wait_mode, inp.p_idle_wait, mu1=inp.mu1, mu2=inp.mu2,
        per_level_n_ckpt=True)
    fold = strategies.evaluate_strategies_fold(
        t_comp, t_failed, [n_ckpt[..., f] for f in range(4)], inp.dur,
        inp.ladder, inp.sleep, wait_mode, inp.p_idle_wait,
        mu1=inp.mu1, mu2=inp.mu2)
    assert not bool(np.all(np.asarray(ref.feasible_any)))  # both branches hit
    for field in ("level", "comp_changed", "wait_action", "feasible_any"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field)), np.asarray(getattr(fold, field)),
            err_msg=field)
    for field in ("freq_ghz", "comp_time", "wait_time", "energy_intervened",
                  "energy_reference", "saving", "saving_pct"):
        np.testing.assert_allclose(
            np.asarray(getattr(ref, field)), np.asarray(getattr(fold, field)),
            rtol=1e-5, atol=1.0, err_msg=field)


# ---------------------------------------------------------------------------
# property: device == host on whole-run energies under random histories
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_device_matches_host_energies_random_keys(seed):
    """Acceptance bar: whole-run energy_ref / energy_int / saving within
    1e-4 relative of the host float64 oracle, per run, on all six Table-4
    scenarios, for random PRNG keys (>= 2 expected failures per run)."""
    cfgs = [paper_scenarios()[n] for n in SCENARIOS]
    key = jax.random.PRNGKey(seed)
    makespan, mtbf = 40000.0, 12000.0   # ~13 expected failures over 4 nodes
    gaps, failed = sweep.renewal_failure_gaps(key, 8, 4, 8, mtbf)
    dev = sweep.renewal_compose_device(cfgs, gaps, makespan, failed_node=failed)
    np.testing.assert_array_equal(np.asarray(dev.gaps), gaps)
    for s, cfg in enumerate(cfgs):
        host = sweep.renewal_compose(cfg, gaps, makespan, failed_node=failed)
        assert host.n_failures.mean() >= 2, cfg.name
        d = _device_slice(dev, s)
        np.testing.assert_array_equal(d.n_failures, host.n_failures)
        np.testing.assert_array_equal(d.failed_node, host.failed_node)
        for field in ("energy_ref", "energy_int"):
            np.testing.assert_allclose(
                getattr(d, field), getattr(host, field),
                rtol=1e-4, err_msg=f"{cfg.name} {field} seed={seed}")
        denom = np.maximum(np.abs(host.saving), 1e-4 * host.energy_ref)
        np.testing.assert_array_less(
            np.abs(d.saving - host.saving) / denom, 1e-4)


# ---------------------------------------------------------------------------
# failure processes: device == host for Weibull / trace-driven histories
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("process", _nonexp_processes(),
                         ids=lambda p: p.label())
def test_device_matches_host_nonexponential_processes(process):
    """Acceptance bar for the failure-process axis: Weibull and
    trace-driven renewal Monte-Carlo cross-validates device-vs-host at
    <= 1e-4 relative on whole-run energies for all six Table-4 scenarios,
    with the fixed-key failure histories bit-identical across engines
    (the device engine samples the conditional-residual scan *inside* its
    fused jitted program; the host oracle samples standalone)."""
    cfgs = [paper_scenarios()[n] for n in SCENARIOS]
    key = jax.random.PRNGKey(11)
    makespan = 40000.0
    gaps, failed = sweep.renewal_failure_gaps(key, 8, 4, 8, process=process)
    dev = sweep.renewal_monte_carlo_device(
        cfgs, key, n_runs=8, makespan_s=makespan, max_failures=8,
        process=process)
    np.testing.assert_array_equal(np.asarray(dev.gaps), gaps)      # bitwise
    np.testing.assert_array_equal(
        np.asarray(dev.failed_node)[0],
        np.where(np.asarray(dev.valid)[0], failed, -1))
    for s, cfg in enumerate(cfgs):
        host = sweep.renewal_compose(cfg, gaps, makespan, failed_node=failed)
        assert host.n_failures.mean() >= 2, cfg.name
        np.testing.assert_array_equal(
            np.asarray(dev.n_failures)[s], host.n_failures, err_msg=cfg.name)
        for field in ("energy_ref", "energy_int"):
            np.testing.assert_allclose(
                np.asarray(getattr(dev, field))[s], getattr(host, field),
                rtol=1e-4, err_msg=f"{cfg.name} {field} {process.label()}")
        denom = np.maximum(np.abs(host.saving), 1e-4 * host.energy_ref)
        np.testing.assert_array_less(
            np.abs(np.asarray(dev.saving)[s] - host.saving) / denom, 1e-4)


@pytest.mark.parametrize("process", _nonexp_processes(),
                         ids=lambda p: p.label())
def test_renewal_monte_carlo_engines_pinned_nonexponential(process):
    """Fixed-key determinism pin extended to the new processes: the device
    summary equals the host oracle's — integer fields and histograms
    exactly, floats to float64 round-off — and stays deterministic under
    the same key."""
    cfg = paper_scenarios()["scenario2_long_reexec"]
    kw = dict(n_runs=32, makespan_s=200000.0, max_failures=16)
    dev = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                    engine="device", process=process, **kw)
    host = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                     engine="host", process=process, **kw)
    for field in dev.__dataclass_fields__:
        a, b = getattr(dev, field), getattr(host, field)
        if isinstance(a, float):
            np.testing.assert_allclose(a, b, rtol=1e-9, err_msg=field)
        else:
            assert a == b, (field, a, b)
    # the summary reports the process's mean gap as its MTBF
    np.testing.assert_allclose(
        dev.mtbf_s, float(np.mean(process.mean_s())), rtol=1e-6)
    again = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                      engine="device", process=process, **kw)
    assert again == dev


def test_renewal_scenarios_process_matches_per_scenario_device():
    """The one-dispatch six-scenario path accepts a process and equals
    per-scenario device calls under the same key."""
    cfgs = paper_scenarios()
    w = F.Weibull.from_mtbf(0.7, 9000.0)
    kw = dict(n_runs=16, makespan_s=30000.0, max_failures=8)
    stacked = sweep.renewal_monte_carlo_scenarios(
        list(cfgs.values()), jax.random.PRNGKey(5), process=w, **kw)
    name = SCENARIOS[2]
    single = sweep.renewal_monte_carlo(
        cfgs[name], jax.random.PRNGKey(5), engine="device", process=w, **kw)
    for field in single.__dataclass_fields__:
        a, b = getattr(stacked[name], field), getattr(single, field)
        if isinstance(a, float):
            np.testing.assert_allclose(a, b, rtol=1e-12, err_msg=field)
        else:
            assert a == b, (field, a, b)


# ---------------------------------------------------------------------------
# determinism: renewal_monte_carlo pinned across engines for a fixed key
# ---------------------------------------------------------------------------

def test_renewal_monte_carlo_engines_pinned():
    """Fixed key: the device engine's summary equals the host oracle's —
    integer fields and histograms exactly (bit-identical failure histories
    and decisions), float fields to float64 round-off."""
    cfg = paper_scenarios()["scenario2_long_reexec"]
    kw = dict(n_runs=64, makespan_s=10 * 24 * 3600.0,
              mtbf_s=3 * 24 * 3600.0, max_failures=32)
    dev = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                    engine="device", **kw)
    host = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                     engine="host", **kw)
    for field in dev.__dataclass_fields__:
        a, b = getattr(dev, field), getattr(host, field)
        if isinstance(a, float):
            np.testing.assert_allclose(a, b, rtol=1e-9, err_msg=field)
        else:
            assert a == b, (field, a, b)
    # deterministic under the same key; sensitive to the key
    again = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                      engine="device", **kw)
    assert again == dev
    other = sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(4),
                                      engine="device", **kw)
    assert other.mean_saving_j != dev.mean_saving_j
    with pytest.raises(ValueError, match="engine"):
        sweep.renewal_monte_carlo(cfg, jax.random.PRNGKey(3),
                                  engine="gpu", **kw)


def test_renewal_monte_carlo_scenarios_one_dispatch_matches_per_scenario():
    """The stacked six-scenario summary dict equals per-scenario device
    calls with the same key (same histories hit every scenario)."""
    cfgs = paper_scenarios()
    kw = dict(n_runs=32, makespan_s=30000.0, mtbf_s=9000.0, max_failures=16)
    stacked = sweep.renewal_monte_carlo_scenarios(
        list(cfgs.values()), jax.random.PRNGKey(5), **kw)
    assert sorted(stacked) == SCENARIOS
    for name in (SCENARIOS[0], SCENARIOS[3]):
        single = sweep.renewal_monte_carlo(
            cfgs[name], jax.random.PRNGKey(5), engine="device", **kw)
        for field in single.__dataclass_fields__:
            a, b = getattr(stacked[name], field), getattr(single, field)
            if isinstance(a, float):
                # energy sums may tile differently across batch sizes
                np.testing.assert_allclose(a, b, rtol=1e-12,
                                           err_msg=f"{name} {field}")
            else:
                assert a == b, (name, field, a, b)


# ---------------------------------------------------------------------------
# occurrence / truncation semantics at the makespan boundary (bugfix)
# ---------------------------------------------------------------------------

def test_gap_landing_exactly_on_makespan_occurs_in_both_paths():
    """A failure gap consuming exactly the remaining makespan still occurs
    (<= comparison), in the host oracle, the device scan, and the event
    simulator; the run is complete (not truncated) afterwards.  A gap one
    ulp past the makespan is dropped and the run is not truncated either
    (its next failure genuinely lands past the end)."""
    cfg = paper_scenarios()["scenario4_short_active_waits"]
    makespan = 20000.0

    # 20000 s from a fresh anchor avoids mid-checkpoint snapping (timers at
    # 3540 + k*3720 wall seconds), so bal_elapsed hits the makespan exactly
    on = np.array([[makespan, 1.0]])
    host_on = sweep.renewal_compose(cfg, on, makespan)
    dev_on = sweep.renewal_compose_device(cfg, on, makespan)
    run_on = simulate_run(cfg, on[0], makespan)
    assert int(host_on.n_failures[0]) == 1
    assert int(np.asarray(dev_on.n_failures)[0, 0]) == 1
    assert run_on.n_failures == 1
    # the epoch consumed the whole makespan: complete, not truncated
    assert not bool(host_on.truncated[0])
    assert not bool(np.asarray(dev_on.truncated)[0, 0])
    np.testing.assert_allclose(
        float(np.asarray(dev_on.energy_ref)[0, 0]), run_on.energy_ref,
        rtol=1e-4)
    np.testing.assert_allclose(
        float(np.asarray(dev_on.energy_ref)[0, 0]), host_on.energy_ref[0],
        rtol=1e-9)

    past = np.array([[np.nextafter(makespan, np.inf), 1.0]])
    host_past = sweep.renewal_compose(cfg, past, makespan)
    dev_past = sweep.renewal_compose_device(cfg, past, makespan)
    assert int(host_past.n_failures[0]) == 0
    assert int(np.asarray(dev_past.n_failures)[0, 0]) == 0
    assert not bool(host_past.truncated[0])      # killed by an overlong gap,
    assert not bool(np.asarray(dev_past.truncated)[0, 0])  # never truncated
    assert simulate_run(cfg, past[0], makespan).n_failures == 0


def test_truncation_semantics_identical_across_paths():
    """Runs that exhaust max_failures with balanced time left are truncated
    in both paths; dead runs zero out identically (n_failures, valid)."""
    cfg = paper_scenarios()["scenario4_short_active_waits"]
    gaps = np.array([
        [2000.0, 3000.0],       # exhausts both gaps well before the makespan
        [2000.0, 1e9],          # killed at epoch 1
        [1e9, 100.0],           # killed at epoch 0: later short gap dropped
    ])
    host = sweep.renewal_compose(cfg, gaps, MAKESPAN)
    dev = sweep.renewal_compose_device(cfg, gaps, MAKESPAN)
    np.testing.assert_array_equal(host.n_failures, [2, 1, 0])
    np.testing.assert_array_equal(np.asarray(dev.n_failures)[0], [2, 1, 0])
    np.testing.assert_array_equal(host.truncated, [True, False, False])
    np.testing.assert_array_equal(np.asarray(dev.truncated)[0],
                                  [True, False, False])
    np.testing.assert_array_equal(np.asarray(dev.valid)[0], host.valid)
    np.testing.assert_allclose(np.asarray(dev.energy_ref)[0],
                               host.energy_ref, rtol=1e-9)


# ---------------------------------------------------------------------------
# input validation mirrors the host path
# ---------------------------------------------------------------------------

def test_device_inputs_validated_like_host():
    cfgs = paper_scenarios()
    slowed = cfgs["scenario4_short_active_waits"]
    slowed = dataclasses.replace(slowed, survivors=tuple(
        dataclasses.replace(sv, level=1) for sv in slowed.survivors))
    with pytest.raises(ValueError, match="balanced"):
        sweep.renewal_compose_device(slowed, GAPS, MAKESPAN)
    with pytest.raises(ValueError, match="no scenarios"):
        sweep.renewal_compose_device([], GAPS, MAKESPAN)
    # stacking requires shared survivor count
    two = dataclasses.replace(
        cfgs["scenario1_short_reexec"],
        survivors=cfgs["scenario1_short_reexec"].survivors[:2])
    with pytest.raises(ValueError, match="survivor count"):
        sweep.renewal_compose_device(
            [cfgs["scenario2_long_reexec"], two], GAPS, MAKESPAN)
