"""Property-based tests for the Algorithm-1 strategy engine.

The invariants below are the paper's stated guarantees:
  * the selected frequency never makes the recovered process wait
    (comp_time <= T_failed);
  * intervention never consumes more energy than the reference (saving >= 0);
  * the selection is the argmin over feasible ladder levels;
  * vectorized evaluation == per-node evaluation.
"""
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import energy_model as em
from repro.core import strategies
from repro.core.characterization import paper_machine_profile, tpu_v5e_like_profile

PROFILES = [paper_machine_profile(), tpu_v5e_like_profile()]

node_inputs = st.tuples(
    st.floats(min_value=1.0, max_value=5000.0),     # t_comp_fa
    st.floats(min_value=0.0, max_value=10000.0),    # extra slack -> t_failed
    st.integers(min_value=0, max_value=3),          # n_ckpt
    st.sampled_from([em.WaitMode.ACTIVE, em.WaitMode.IDLE]),
    st.integers(min_value=0, max_value=1),          # profile index
)


def _decide(t_comp, slack, n_ckpt, wait_mode, profile):
    t_ckpt = 120.0
    # by construction fa is feasible: t_failed >= comp_time(fa)
    t_failed = t_comp + n_ckpt * t_ckpt + slack
    return (
        strategies.evaluate_strategies_profile(
            profile, t_comp, t_failed, float(n_ckpt), t_ckpt, int(wait_mode)
        ),
        t_failed,
    )


@settings(max_examples=200, deadline=None)
@given(node_inputs)
def test_never_delays_recovered_process(inp):
    t_comp, slack, n_ckpt, wait_mode, pidx = inp
    d, t_failed = _decide(t_comp, slack, n_ckpt, wait_mode, PROFILES[pidx])
    assert bool(d.feasible_any)
    assert float(d.comp_time) <= t_failed * (1 + 1e-5)
    assert float(d.wait_time) >= -1e-3


@settings(max_examples=200, deadline=None)
@given(node_inputs)
def test_saving_nonnegative(inp):
    t_comp, slack, n_ckpt, wait_mode, pidx = inp
    d, _ = _decide(t_comp, slack, n_ckpt, wait_mode, PROFILES[pidx])
    assert float(d.saving) >= -0.1  # float32 ULP tolerance at ~1e5 J scale
    assert float(d.energy_intervened) <= float(d.energy_reference) + 0.1


@settings(max_examples=100, deadline=None)
@given(node_inputs)
def test_selection_is_argmin(inp):
    t_comp, slack, n_ckpt, wait_mode, pidx = inp
    profile = PROFILES[pidx]
    d, t_failed = _decide(t_comp, slack, n_ckpt, wait_mode, profile)
    ladder = em.LadderArrays.from_table(profile.power_table)
    sleep = em.SleepArrays.from_spec(profile.sleep)
    out = em.intervention_energy(
        jnp.asarray(t_comp, jnp.float32), jnp.asarray(t_failed, jnp.float32),
        jnp.asarray(float(n_ckpt), jnp.float32), 120.0, ladder, sleep,
        jnp.asarray(int(wait_mode), jnp.int32), profile.p_idle_wait, mu1=6.0,
    )
    totals = np.asarray(out["total"])
    # fused-jit vs eager differ by a couple of float32 ULPs
    assert float(d.energy_intervened) <= np.min(totals) * (1 + 1e-5) + 1e-2


@settings(max_examples=50, deadline=None)
@given(st.lists(node_inputs, min_size=2, max_size=16))
def test_vectorized_matches_scalar(batch):
    """One batched call == N scalar calls (the scale-out claim)."""
    pidx = batch[0][4]
    profile = PROFILES[pidx]
    t_comp = np.array([b[0] for b in batch], np.float32)
    n_ckpt = np.array([float(b[2]) for b in batch], np.float32)
    t_failed = t_comp + n_ckpt * 120.0 + np.array([b[1] for b in batch], np.float32)
    modes = np.array([int(b[3]) for b in batch], np.int32)
    d = strategies.evaluate_strategies_profile(
        profile, t_comp, t_failed, n_ckpt, 120.0, modes
    )
    for i in range(len(batch)):
        di = strategies.evaluate_strategies_profile(
            profile, t_comp[i], t_failed[i], n_ckpt[i], 120.0, modes[i]
        )
        assert int(np.asarray(d.level)[i]) == int(di.level)
        assert int(np.asarray(d.wait_action)[i]) == int(di.wait_action)
        np.testing.assert_allclose(
            np.asarray(d.saving)[i], float(di.saving), rtol=5e-4, atol=0.5
        )


def test_monte_carlo_grid_shape():
    """Failure-time sweeps batch along leading axes (T, N)."""
    profile = paper_machine_profile()
    t_comp = np.linspace(10, 1000, 8)[:, None] * np.ones((1, 5))
    t_failed = t_comp + np.linspace(0, 4000, 5)[None, :]
    d = strategies.evaluate_strategies_profile(
        profile, t_comp, t_failed, 0.0, 120.0, em.WaitMode.ACTIVE
    )
    assert d.level.shape == (8, 5)
    assert np.all(np.asarray(d.saving) >= -1e-2)


def test_mu1_band_and_defaults():
    """The Table-4 decisions pin mu1 to the open band (110/30, 230/30) ~=
    (3.67, 7.67); both evaluation entry points must default inside it (the
    docstring's derivation, regression-pinned here against the defaults)."""
    import inspect

    lo, hi = 110.0 / 30.0, 230.0 / 30.0
    for fn in (strategies.evaluate_strategies, strategies.evaluate_strategies_profile):
        default = inspect.signature(fn).parameters["mu1"].default
        assert default == 6.0, fn.__name__
        assert lo < default < hi, fn.__name__

    profile = paper_machine_profile()

    def sleeps(t_comp, t_failed, n_ckpt, mu1):
        d = strategies.evaluate_strategies_profile(
            profile, t_comp, t_failed, n_ckpt, 120.0, int(em.WaitMode.ACTIVE),
            mu1=mu1,
        )
        return int(d.wait_action) == em.WaitAction.SLEEP

    # scenario 1 node 1 (110 s wait, must NOT sleep) fixes the lower edge;
    # nodes 2-3 (230 s wait, MUST sleep) fix the upper edge.  Decisions hold
    # for every mu1 inside the band — including the defaults and all four
    # integers — and flip just outside it.
    for mu1 in (lo + 1e-3, 4.0, 5.0, 6.0, 7.0, hi - 1e-3):
        assert not sleeps(972.0, 1202.0, 1.0, mu1), mu1   # wait = 110 s
        assert sleeps(103.8, 333.8, 0.0, mu1), mu1        # wait = 230 s
    assert sleeps(972.0, 1202.0, 1.0, lo - 0.1)           # gate too loose
    assert not sleeps(103.8, 333.8, 0.0, hi + 0.1)        # gate too tight


def test_known_decisions_table4():
    """Spot-check the four decision regimes of Table 4 (one per scenario
    family); the full rows are covered in test_scenarios.py."""
    profile = paper_machine_profile()
    # scenario 1 node 1: wait 110 s -> min-freq, no comp change
    d = strategies.evaluate_strategies_profile(
        profile, 972.0, 1202.0, 1.0, 120.0, em.WaitMode.ACTIVE
    )
    assert int(d.level) == 0 and int(d.wait_action) == em.WaitAction.MIN_FREQ
    np.testing.assert_allclose(float(d.saving), 4400.0, rtol=1e-4)
    # scenario 2 node 1: long wait -> sleep, no comp change
    d = strategies.evaluate_strategies_profile(
        profile, 481.2, 2521.2, 1.0, 120.0, em.WaitMode.ACTIVE
    )
    assert int(d.level) == 0 and int(d.wait_action) == em.WaitAction.SLEEP
    np.testing.assert_allclose(float(d.saving), 294310.0, rtol=1e-4)
    # scenario 4 node 2: 1.7 GHz comp + min-freq wait
    d = strategies.evaluate_strategies_profile(
        profile, 166.0, 325.8, 0.0, 120.0, em.WaitMode.ACTIVE
    )
    np.testing.assert_allclose(float(d.freq_ghz), 1.7, rtol=1e-6)
    assert int(d.wait_action) == em.WaitAction.MIN_FREQ
    # scenario 5 node 1: idle waits -> 2.1 GHz comp, no wait action
    d = strategies.evaluate_strategies_profile(
        profile, 141.0, 300.8, 0.0, 120.0, em.WaitMode.IDLE
    )
    np.testing.assert_allclose(float(d.freq_ghz), 2.1, rtol=1e-6)
    assert int(d.wait_action) == em.WaitAction.NONE
