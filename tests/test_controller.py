"""Closed-loop tests: FTTrainer <-> renewal-engine cross-validation.

The trainer is driven by the *same* failure histories the device renewal
engine samples (shared PRNG key), so its realized energy ledger can be
reconciled against the engine two ways:

  * exactly — ``renewal_compose`` on the realized gap sequence (same
    float32 Algorithm-1 dispatch, same float64 closed-form geometry) must
    match the ledger to float tolerance;
  * in expectation — ``renewal_monte_carlo_device`` at the injector's key
    predicts the same run within a step-quantization-bounded tolerance
    (the trainer rounds failure instants to step boundaries; the sampled
    instants land mid-step).  Observed ~8 % at step 100 s vs cluster
    MTBF ~500 s; pinned at < 12 %.  See docs/runtime.md.

The model here is a tiny jitted update (not the real transformer): the
energy loop touches only step *counts* and wall clocks, and the real-model
path is covered by tests/test_ft.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointConfig
from repro.core import failures, optimize, sweep
from repro.ft.controller import (AdaptiveController, StochasticFailureInjector,
                                 cluster_scenario, reconcile_ledger)
from repro.ft.runtime import ClusterSpec, FTTrainer

KEY = jax.random.PRNGKey(3)
N_PODS = 4
STEP_S = 100.0
DUR_S = 120.0
PROCESS = failures.Weibull.from_mtbf(0.7, 2000.0)


class TinyPipeline:
    def batch_at(self, step):
        return jnp.full((4,), float(step))


@jax.jit
def _tiny_step(params, opt_state, batch):
    g = jnp.mean(batch) * 0.01
    params = jax.tree.map(lambda p: p - 0.001 * (p + g), params)
    return params, opt_state, {"total_loss": jnp.mean(batch)}


def _injector(max_failures=32, n_runs=4, run_index=1, process=PROCESS):
    return StochasticFailureInjector(process, KEY, n_pods=N_PODS,
                                     max_failures=max_failures,
                                     n_runs=n_runs, run_index=run_index)


def _trainer(root, *, injector, interval_steps=6, controller=None,
             **kwargs):
    state = ({"w": jnp.ones((8,))}, {"m": jnp.zeros((8,))})
    return FTTrainer(
        step_fn=_tiny_step, pipeline=TinyPipeline(), state=state,
        cluster=ClusterSpec(n_pods=N_PODS, step_time_s=STEP_S),
        ckpt_cfg=CheckpointConfig(root=str(root),
                                  interval_steps=interval_steps, keep=3,
                                  phase_offset_steps=1),
        injector=injector, ckpt_duration_s=DUR_S, controller=controller,
        **kwargs)


# ---------------------------------------------------------------------------
# injector <-> engine history identity
# ---------------------------------------------------------------------------

def test_injector_replays_engine_history():
    inj = _injector()
    gaps, failed = sweep.renewal_failure_gaps(KEY, 4, N_PODS, 32,
                                              process=PROCESS)
    np.testing.assert_array_equal(inj.gaps, gaps[1])
    np.testing.assert_array_equal(inj.failed_node, failed[1])
    # poll semantics: fires at the first boundary whose step would cross
    # the sampled gap, then confirm() arms the next epoch
    first = float(inj.gaps[0])
    assert inj.poll(0, first - STEP_S - 1.0, STEP_S) is None
    pod = inj.poll(0, first - 0.5 * STEP_S, STEP_S)
    assert pod == int(inj.failed_node[0])
    inj.confirm(0)
    assert inj.n_fired == 1

    with pytest.raises(ValueError):
        StochasticFailureInjector(PROCESS, KEY, n_pods=N_PODS, n_runs=2,
                                  run_index=2)


# ---------------------------------------------------------------------------
# end-to-end reconciliation (acceptance criterion)
# ---------------------------------------------------------------------------

def test_ledger_reconciles_with_renewal_engine(tmp_path):
    tr = _trainer(tmp_path / "ck", injector=_injector())
    tr.run(60)
    assert len(tr.events) >= 3          # a genuinely multi-failure run

    rep = reconcile_ledger(tr)
    assert rep.n_failures == len(tr.events)
    # exact check: the host oracle on the realized gaps reproduces the
    # ledger (same f32 Algorithm-1 bits, same f64 balanced/epoch closed
    # forms) — accounting drift would show up here
    assert rep.rel_err_compose < 1e-5
    # expectation check: the device Monte Carlo's prediction for this run
    # index at the shared key, within the documented step-quantization
    # tolerance
    assert rep.mc_j is not None
    assert rep.rel_err_mc < 0.12
    # the ledger decomposes into steady-state + epoch windows
    em_ = tr.energy
    total = em_.steps_j + em_.ckpt_j + em_.resync_j \
        + sum(e.epoch_int_j for e in em_.events)
    assert rep.ledger_j == pytest.approx(total)
    assert em_.ledger_reference_j() >= em_.ledger_total_j()


def test_ledger_reconciles_without_failures(tmp_path):
    calm = failures.Exponential(mtbf_s=1e12)
    tr = _trainer(tmp_path / "ck", injector=_injector(process=calm))
    tr.run(24)
    assert tr.events == []
    rep = reconcile_ledger(tr, mc=False)
    # pure balanced run: steps + checkpoint writes match the engine's
    # balanced-span partition exactly
    assert rep.rel_err_compose < 1e-9
    assert tr.energy.resync_j == 0.0


def test_run_is_deterministic_bit_for_bit(tmp_path):
    runs = []
    for sub in ("a", "b"):
        tr = _trainer(tmp_path / sub, injector=_injector())
        tr.run(40)
        runs.append(tr)
    a, b = runs
    assert a.energy.ledger_total_j() == b.energy.ledger_total_j()
    assert [e["gap_s"] for e in a.events] == [e["gap_s"] for e in b.events]
    assert [e.epoch_int_j for e in a.energy.events] == \
        [e.epoch_int_j for e in b.energy.events]


# ---------------------------------------------------------------------------
# adaptive controller (acceptance criterion)
# ---------------------------------------------------------------------------

def test_adaptive_controller_beats_static_default(tmp_path):
    # deliberately bad static default: checkpoint every step (write time
    # exceeds half the step time)
    static = _trainer(tmp_path / "s", injector=_injector(),
                      interval_steps=1)
    static.run(60)
    static_j = static.energy.ledger_total_j()

    prior = failures.Exponential(mtbf_s=8000.0)
    ctl = AdaptiveController(prior, n_pods=N_PODS, retune_every=2,
                             min_complete_gaps=3, cem_iters=2,
                             cem_population=10, cem_n_runs=32,
                             cem_max_failures=32, seed=0)
    adaptive = _trainer(tmp_path / "a", injector=_injector(),
                        interval_steps=1, controller=ctl)
    adaptive.run(60)
    adaptive_j = adaptive.energy.ledger_total_j()

    # the controller actually observed, fitted, and pushed a new policy
    assert ctl.retunes
    assert ctl.fitted is not None
    assert adaptive.cluster.ckpt_interval_s != static.cluster.ckpt_interval_s
    assert adaptive.managers[0].cfg.interval_steps > 1
    assert any(e["policy"] is not None for e in adaptive.events)
    # cadence spec and live managers agree after the push
    assert adaptive.cluster.ckpt_interval_s == pytest.approx(
        adaptive.managers[0].cfg.interval_steps * STEP_S)

    # realized: tuned run spends no more than the static default on the
    # same injected failure history
    assert adaptive_j < static_j

    # engine CRN comparison: the final tuned policy is no worse than the
    # static default policy in expectation over shared histories
    cl = static.cluster
    fin = adaptive.cluster
    table = optimize.PolicyTable(
        ckpt_interval=np.asarray([cl.ckpt_interval_s, fin.ckpt_interval_s]),
        mu1=np.asarray([cl.mu1, fin.mu1]),
        mu2=np.asarray([cl.mu2, fin.mu2]),
        wait_mode=np.asarray([int(cl.wait_mode), int(fin.wait_mode)],
                             np.int32),
        move_ahead_frac=np.asarray([cl.move_ahead_frac,
                                    fin.move_ahead_frac]))
    res = optimize.evaluate_policy_grid(
        cluster_scenario(cl, ckpt_duration_s=DUR_S), table,
        jax.random.PRNGKey(11), work_s=6000.0, n_runs=64, max_failures=32,
        process=PROCESS)
    assert res.mean_energy_j[1] <= res.mean_energy_j[0]


def test_observe_fit_competing_risks():
    ctl = AdaptiveController(failures.Exponential(mtbf_s=1000.0),
                             n_pods=3, min_complete_gaps=3)
    # clocks: all advance by each gap, the failed node's resets
    ctl.observe_failure(gap_s=100.0, failed_pod=0)
    np.testing.assert_allclose(ctl._ages, [0.0, 100.0, 100.0])
    assert ctl.complete_gaps == [100.0]
    assert ctl.fit() is None            # below min_complete_gaps
    ctl.observe_failure(gap_s=50.0, failed_pod=1)
    assert ctl.complete_gaps[-1] == 150.0   # age 100 + gap 50
    ctl.observe_failure(gap_s=200.0, failed_pod=0)
    np.testing.assert_allclose(ctl._ages, [0.0, 200.0, 350.0])
    fitted = ctl.fit()
    assert isinstance(fitted, failures.Weibull)
    k = float(np.asarray(fitted.k))
    assert ctl.k_bounds[0] <= k <= ctl.k_bounds[1]
    # zero-quantized lifetimes don't count toward the fitting threshold
    ctl2 = AdaptiveController(failures.Exponential(mtbf_s=1000.0),
                              n_pods=3, min_complete_gaps=3)
    for _ in range(5):
        ctl2.observe_failure(gap_s=0.0, failed_pod=0)
    assert ctl2.fit() is None


def test_cluster_scenario_geometry():
    cl = ClusterSpec(n_pods=4, step_time_s=100.0)
    cfg = cluster_scenario(cl, ckpt_duration_s=60.0, ckpt_interval_s=600.0)
    assert len(cfg.survivors) == 3
    for s in cfg.survivors:
        assert s.exec_to_rendezvous == 100.0
        assert s.rendezvous_period == 100.0
        assert s.ckpt_age == 0.0
    assert cfg.t_reexec == 0.0
    assert cfg.ckpt_interval == 600.0
    with pytest.raises(ValueError):
        cluster_scenario(ClusterSpec(n_pods=1))
