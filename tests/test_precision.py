"""Precision-regime regression tests.

Two bugfix families pinned here:

* the device-input cache (``sweep._renewal_device_inputs``) keys on the
  *effective* dtype regime as well as config content — toggling x64
  around a cached call, or interleaving the f32 Pallas engine with the
  x64 scan, must never serve stale-dtype stacked inputs;
* the float32 casts the Pallas engine applies to float64-built inputs
  (``sweep._pack_pallas_inputs``, the policy-stack cast in
  ``renewal_monte_carlo_policies``) are *bit-exact* for every value the
  configs carry, so the policy path and the scenario path feed the
  kernel identical bits — the CRN cross-validation in
  tests/test_renewal_pallas.py rests on this.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import optimize, sweep
from repro.core import scenarios as scen_mod
from repro.core.scenarios import paper_scenarios


def _float_leaves(tree):
    return [a for a in jax.tree.leaves(tree)
            if jnp.issubdtype(a.dtype, jnp.floating)]


def _int_leaves(tree):
    return [a for a in jax.tree.leaves(tree)
            if not jnp.issubdtype(a.dtype, jnp.floating)]


# ---------------------------------------------------------------------------
# the device-input cache vs the x64 regime
# ---------------------------------------------------------------------------

def test_cache_keys_on_effective_dtype_regime():
    """The regression: a content-keyed cache would serve the float32 entry
    to the x64 scan engine (or the float64 entry to the Pallas engine)
    once both run in one process.  The key must include the regime, and
    repeated same-regime calls must still hit."""
    sweep._renewal_inputs_cache.clear()
    cfgs = list(paper_scenarios().values())

    _, s32 = sweep._renewal_device_inputs(cfgs, jnp.float32)
    assert all(a.dtype == jnp.float32 for a in _float_leaves(s32))

    with enable_x64():
        _, s64 = sweep._renewal_device_inputs(cfgs, jnp.float64)
        assert all(a.dtype == jnp.float64 for a in _float_leaves(s64))

    # same content, both regimes resident: each regime hits its own entry
    with enable_x64():
        _, again64 = sweep._renewal_device_inputs(cfgs, jnp.float64)
    _, again32 = sweep._renewal_device_inputs(cfgs, jnp.float32)
    assert again64 is s64 and again32 is s32
    assert all(a.dtype == jnp.float32 for a in _float_leaves(again32))


def test_cache_f64_request_outside_x64_is_the_f32_regime():
    """A float64 request outside ``enable_x64`` *builds float32 arrays*
    (JAX demotes), so it must share the float32 entry — and must NOT
    poison the real float64 regime, which still gets fresh x64 arrays."""
    sweep._renewal_inputs_cache.clear()
    cfgs = list(paper_scenarios().values())

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)    # JAX demotion notice
        _, demoted = sweep._renewal_device_inputs(cfgs, jnp.float64)  # no x64
    assert all(a.dtype == jnp.float32 for a in _float_leaves(demoted))

    _, s32 = sweep._renewal_device_inputs(cfgs, jnp.float32)
    assert s32 is demoted                     # one entry, correctly shared

    with enable_x64():
        _, s64 = sweep._renewal_device_inputs(cfgs, jnp.float64)
    assert s64 is not demoted
    assert all(a.dtype == jnp.float64 for a in _float_leaves(s64))


# ---------------------------------------------------------------------------
# float32 casts of float64-built inputs are bit-exact (the Pallas feed)
# ---------------------------------------------------------------------------

def test_scenario_inputs_f32_cast_of_f64_is_bit_exact():
    """Every float leaf of the six-scenario stack: building in float64 and
    casting to float32 gives bit-for-bit the direct float32 build — the
    config values (durations, powers, fractions) all round-trip."""
    sweep._renewal_inputs_cache.clear()
    cfgs = list(paper_scenarios().values())
    _, s32 = sweep._renewal_device_inputs(cfgs, jnp.float32)
    with enable_x64():
        _, s64 = sweep._renewal_device_inputs(cfgs, jnp.float64)
    for a32, a64 in zip(_float_leaves(s32), _float_leaves(s64)):
        np.testing.assert_array_equal(np.asarray(a32),
                                      np.asarray(a64, np.float32))
    for i32, i64 in zip(_int_leaves(s32), _int_leaves(s64)):
        np.testing.assert_array_equal(np.asarray(i32), np.asarray(i64))


def test_policy_lane_f32_cast_matches_direct_f32_build():
    """Lane ``p`` of the float64 policy stack (``optimize.policy_inputs``),
    cast to float32 the way the Pallas policy path does, equals the direct
    float32 ``sweep_inputs`` of that policy's config — so the policy grid
    and standalone scenario calls feed the kernel identical bits (the CRN
    bit-identity test in tests/test_renewal_pallas.py observes this from
    the outside; this pins the mechanism)."""
    cfg = paper_scenarios()["scenario2_long_reexec"]
    table = optimize.default_policy_table(cfg, 12000.0)
    stacked = optimize.policy_inputs(cfg, table)
    cast = (lambda a: a.astype(jnp.float32)
            if jnp.issubdtype(a.dtype, jnp.floating) else a)
    stacked32 = jax.tree.map(cast, stacked)
    for p in (0, 3, len(table) - 1):
        lane = jax.tree.map(lambda a, p=p: a[p], stacked32)
        cfg_p = scen_mod.apply_policy(cfg, **table.policy(p))
        direct = sweep.sweep_inputs(cfg_p, jnp.float32)
        for a, b in zip(jax.tree.leaves(lane), jax.tree.leaves(direct)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"policy {p}")


def test_pallas_pack_identical_from_either_regime():
    """The packed kernel operands (params row, node block, ladder) are
    bit-identical whether built from the float32 stack or the float64
    stack cast down — the two engine entry paths."""
    sweep._renewal_inputs_cache.clear()
    cfgs = list(paper_scenarios().values())
    _, s32 = sweep._renewal_device_inputs(cfgs, jnp.float32)
    with enable_x64():
        _, s64 = sweep._renewal_device_inputs(cfgs, jnp.float64)
    cast = (lambda a: a.astype(jnp.float32)
            if jnp.issubdtype(a.dtype, jnp.floating) else a)
    a_pack = sweep._pack_pallas_inputs(s32, 12345.0)
    b_pack = sweep._pack_pallas_inputs(jax.tree.map(cast, s64), 12345.0)
    for a, b in zip(a_pack, b_pack):
        assert a.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
