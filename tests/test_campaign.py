"""Campaign engine tests: spec composition, the content-hash contract,
store durability, and the runner's resume/chunking bit-identity.

The load-bearing guarantees pinned here:

  * ``store.cell_key`` is invariant to axis ordering and dict insertion
    order but changes when ANY resolved field changes (property-tested);
  * resume recomputes ZERO completed cells, and a full re-run at the same
    key is bit-identical (canonical JSON of the ``result`` payload);
  * chunking is invisible: forcing 1-lane chunks produces byte-equal
    records vs one fused dispatch;
  * the stacked campaign path reproduces ``renewal_monte_carlo_scenarios``
    exactly (the CRN contract that makes all of the above safe).
"""
import json

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.campaign import analyze, presets, runner, spec, store
from repro.core import sweep

# small-but-real shape shared by every dispatching test in this module so
# the jitted program compiles once
N_RUNS, MAX_FAILURES = 16, 8
MAKESPAN_S = 10.0 * 24 * 3600.0
MTBF_S = 7.0 * 24 * 3600.0

SCEN_A = "scenario2_long_reexec"
SCEN_B = "scenario4_short_active_waits"


def _axes():
    scen = spec.axis("scenario", [(n, {"scenario": {"base": n}})
                                  for n in (SCEN_A, SCEN_B)])
    proc = spec.axis("process", [
        ("exp", {"process": {"kind": "exponential", "mtbf_s": MTBF_S}}),
        ("wb07", {"process": {"kind": "weibull", "k": 0.7,
                              "mtbf_s": MTBF_S}})])
    return scen, proc


def _base():
    return {"run": {"n_runs": N_RUNS, "max_failures": MAX_FAILURES,
                    "makespan_s": MAKESPAN_S},
            "seed": 0}


def _campaign(name="t"):
    scen, proc = _axes()
    return spec.campaign(name, scen * proc, base=_base())


# ---------------------------------------------------------------------------
# spec composition
# ---------------------------------------------------------------------------

def test_cartesian_product_counts_and_labels():
    scen, proc = _axes()
    m = scen * proc
    assert len(m) == 4
    assert m.cells[0].label_dict == {"scenario": SCEN_A, "process": "exp"}
    assert m.cells[0].cell_id() == f"scenario={SCEN_A}/process=exp"
    # C-order: the right axis varies fastest
    assert [c.label_dict["process"] for c in m.cells] == \
        ["exp", "wb07", "exp", "wb07"]


def test_zip_pairs_and_rejects_length_mismatch():
    scen, proc = _axes()
    z = scen.zip(spec.axis("mtbf", [
        ("short", {"process": {"kind": "exponential", "mtbf_s": 1e5}}),
        ("long", {"process": {"kind": "exponential", "mtbf_s": 1e6}})]))
    assert len(z) == 2
    assert z.cells[1].config["process"]["mtbf_s"] == 1e6
    three = spec.axis("seed", [(str(i), {"seed": i}) for i in range(3)])
    with pytest.raises(ValueError, match="equal lengths"):
        scen.zip(three)


def test_filter_prunes_cells():
    scen, proc = _axes()
    m = (scen * proc).filter(lambda lbl, cfg: lbl["process"] == "exp")
    assert len(m) == 2
    assert all(c.label_dict["process"] == "exp" for c in m.cells)


def test_conflicting_axes_rejected():
    a = spec.axis("a", [("x", {"policy": {"mu1": 3.0}})])
    b = spec.axis("b", [("y", {"policy": {"mu1": 4.0}})])
    with pytest.raises(ValueError, match="conflicting values for 'policy.mu1'"):
        _ = a * b
    # identical values are tolerated (shared pin, not a conflict)
    c = spec.axis("c", [("z", {"policy": {"mu1": 3.0}})])
    assert (a * c).cells[0].config["policy"]["mu1"] == 3.0


def test_duplicate_axis_labels_rejected():
    with pytest.raises(ValueError, match="duplicate labels"):
        spec.axis("a", [("x", {}), ("x", {})])


def test_validation_errors():
    scen, _ = _axes()
    with pytest.raises(ValueError, match="unknown policy knobs"):
        spec.campaign("t", scen, base={
            **_base(), "policy": {"nonsense": 1.0}})
    with pytest.raises(ValueError, match="exactly one of makespan_s"):
        spec.campaign("t", scen, base={
            "run": {"n_runs": 4, "max_failures": 2,
                    "makespan_s": 1e6, "work_s": 1e6},
            "process": {"kind": "exponential", "mtbf_s": MTBF_S}})
    with pytest.raises(ValueError, match="unknown scenario base"):
        spec.campaign("t", spec.axis(
            "s", [("bad", {"scenario": {"base": "no_such"}})]), base=_base())
    with pytest.raises(ValueError, match="non-finite"):
        spec.normalize_config({
            "scenario": {"base": SCEN_A},
            "process": {"kind": "exponential", "mtbf_s": float("nan")},
            "run": {"n_runs": 4, "max_failures": 2, "makespan_s": 1e6}})


def test_duplicate_resolved_cells_rejected():
    scen, _ = _axes()
    dup = spec.axis("p", [("a", {"process": {"kind": "exponential",
                                             "mtbf_s": MTBF_S}}),
                          ("b", {"process": {"kind": "exponential",
                                             "mtbf_s": MTBF_S}})])
    with pytest.raises(ValueError, match="resolve to the same config"):
        spec.campaign("t", scen * dup, base=_base())


def test_policy_grid_preset_matches_optimize_grid_order():
    """Campaign cell order == optimize.policy_grid C-order (record p is
    grid row p — benchmarks/optimize_policy.py depends on this)."""
    from repro.core import optimize
    camp = presets.policy_grid()
    table = optimize.policy_grid(
        ckpt_interval=np.asarray(presets.OPT_INTERVALS),
        mu1=list(presets.OPT_MU1), wait_mode=[0, 1])
    assert len(camp.cells) == len(table)
    for p, cell in enumerate(camp.cells):
        pol = table.policy(p)
        assert cell.config["policy"]["ckpt_interval"] == \
            pytest.approx(float(pol["ckpt_interval"]))
        assert cell.config["policy"]["mu1"] == pytest.approx(float(pol["mu1"]))
        assert cell.config["policy"]["wait_mode"] == int(pol["wait_mode"])


def test_fleet_preset_addresses_cluster_scenarios():
    """The fleet preset's cells lower through the ``fleet_cluster``
    registry entry — node-count x power-class matrix over the same
    balanced snapshot the advisor serves (repro.fleet)."""
    from repro.fleet import cluster_scenario
    camp = presets.fleet()
    assert len(camp.cells) == 6                 # 2 node counts x 3 power classes
    for cell in camp.cells:
        sc = cell.config["scenario"]
        assert sc["base"] == "fleet_cluster"
        cfg = spec.build_scenario(sc)
        ref = cluster_scenario(
            **{k: v for k, v in sc.items() if k != "base"})
        assert cfg.name == ref.name
        assert cfg.survivors == ref.survivors
        assert cfg.profile.p_base == ref.profile.p_base
        assert len(cfg.survivors) == sc["n_nodes"] - 1


def test_custom_registration_never_suppresses_builtins(monkeypatch):
    """Registering a custom scenario into a FRESH registry must not
    pre-populate the dict and suppress the builtin scenarios (the old
    dict-non-empty check did exactly that)."""
    monkeypatch.setattr(spec, "_SCENARIO_BUILDERS", {})
    monkeypatch.setattr(spec, "_builtins_done", False)
    spec.register_scenario("custom_probe", lambda: None)
    names = spec.scenario_names()
    assert "custom_probe" in names
    assert "sparse_rendezvous" in names         # builtins survived
    assert SCEN_A in names


# ---------------------------------------------------------------------------
# content-hash contract
# ---------------------------------------------------------------------------

def _config(mtbf=MTBF_S, n_runs=N_RUNS, seed=0, interval=None):
    cfg = {"scenario": {"base": SCEN_A},
           "process": {"kind": "exponential", "mtbf_s": mtbf},
           "run": {"n_runs": n_runs, "max_failures": MAX_FAILURES,
                   "makespan_s": MAKESPAN_S},
           "seed": seed}
    if interval is not None:
        cfg["policy"] = {"ckpt_interval": interval}
    return cfg


def _reordered(d):
    """Same mapping, reversed insertion order at every level."""
    if isinstance(d, dict):
        return {k: _reordered(d[k]) for k in reversed(list(d))}
    return d


def test_cell_key_invariant_to_dict_key_order():
    cfg = spec.normalize_config(_config(interval=3600.0))
    assert store.cell_key(cfg) == store.cell_key(_reordered(cfg))


def test_cell_key_invariant_to_axis_ordering():
    """scenario x process and process x scenario declare the same cells —
    identical content addresses, whatever the composition order."""
    scen, proc = _axes()
    keys_ab = {store.cell_key(c.config)
               for c in spec.campaign("ab", scen * proc, base=_base()).cells}
    keys_ba = {store.cell_key(c.config)
               for c in spec.campaign("ba", proc * scen, base=_base()).cells}
    assert keys_ab == keys_ba


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=1e4, max_value=1e7),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=2**31 - 1),
       st.floats(min_value=600.0, max_value=86400.0))
def test_cell_key_changes_on_any_field_change(mtbf, n_runs, seed, interval):
    base_cfg = spec.normalize_config(_config(interval=3600.0))
    key0 = store.cell_key(base_cfg)
    for variant in (
        _config(mtbf=mtbf * 1.0000001, interval=3600.0),
        _config(n_runs=n_runs + N_RUNS, interval=3600.0),
        _config(seed=seed + 1, interval=3600.0),
        _config(interval=interval + 100000.0),
        _config(interval=None),                       # policy key removed
    ):
        assert store.cell_key(spec.normalize_config(variant)) != key0
    # engine version participates too
    assert store.cell_key(base_cfg, engine_version="other") != key0
    # and the hash is stable across normalize calls
    assert store.cell_key(spec.normalize_config(_config(interval=3600.0))) \
        == key0


def test_cell_key_numpy_scalars_hash_like_python_floats():
    a = spec.normalize_config(_config(mtbf=np.float64(MTBF_S)))
    b = spec.normalize_config(_config(mtbf=float(MTBF_S)))
    assert store.cell_key(a) == store.cell_key(b)


# ---------------------------------------------------------------------------
# store durability
# ---------------------------------------------------------------------------

def _fake_record(i):
    return dict(labels={"i": str(i)}, config={"cell": i},
                result={"value": float(i)}, meta={"wall_s": 0.1})


def test_store_roundtrip_and_idempotent_put(tmp_path):
    st_ = store.ResultStore(tmp_path, shard_size=2)
    for i in range(5):
        st_.put(f"k{i}", **_fake_record(i))
    assert len(st_) == 5
    # idempotent: second put returns the original record
    first = st_.get("k0")
    assert st_.put("k0", **_fake_record(99)) is first
    # reload from disk (fresh handle) sees everything, across shards
    st2 = store.ResultStore(tmp_path)
    assert st2.keys() == {f"k{i}" for i in range(5)}
    assert st2.get("k3")["result"] == {"value": 3.0}
    assert len(list((tmp_path / "shards").glob("cells-*.jsonl"))) >= 2


def test_store_skips_torn_trailing_line(tmp_path):
    st_ = store.ResultStore(tmp_path)
    for i in range(3):
        st_.put(f"k{i}", **_fake_record(i))
    shard = next((tmp_path / "shards").glob("cells-*.jsonl"))
    with open(shard, "a") as f:
        f.write('{"key": "k_torn", "labels": {}, "resu')   # kill mid-write
    st2 = store.ResultStore(tmp_path)
    assert st2.keys() == {"k0", "k1", "k2"}
    # the torn cell is simply recomputable
    st2.put("k_torn", **_fake_record(9))
    assert store.ResultStore(tmp_path).has("k_torn")


def test_store_heals_corrupt_or_stale_index(tmp_path):
    """The shards are the source of truth; index.json is a rebuildable
    view.  Any corruption — garbage bytes, truncation, deletion, a stale
    cells mapping, a checksum mismatch — must be healed on open, not
    trusted or crashed on."""
    st_ = store.ResultStore(tmp_path)
    for i in range(3):
        st_.put(f"k{i}", **_fake_record(i))
    good = (tmp_path / "index.json").read_text()
    idx = json.loads(good)
    assert set(idx) == {"version", "engine", "checksum", "cells"}

    def reopen_and_check():
        st2 = store.ResultStore(tmp_path)
        assert st2.keys() == {"k0", "k1", "k2"}
        healed = json.loads((tmp_path / "index.json").read_text())
        assert healed == json.loads(good)

    # garbage bytes
    (tmp_path / "index.json").write_text('{"version": 1, "garb')
    reopen_and_check()
    # deleted outright
    (tmp_path / "index.json").unlink()
    reopen_and_check()
    # stale cells mapping (e.g. an index copied from another store)
    bad = dict(idx)
    bad["cells"] = {"k0": idx["cells"]["k0"]}
    (tmp_path / "index.json").write_text(json.dumps(bad))
    reopen_and_check()
    # checksum mismatch with a plausible-looking cells mapping
    bad = dict(idx)
    bad["checksum"] = "0" * 64
    (tmp_path / "index.json").write_text(json.dumps(bad))
    reopen_and_check()
    # a valid index is left untouched (byte-identical)
    before = (tmp_path / "index.json").read_text()
    store.ResultStore(tmp_path)
    assert (tmp_path / "index.json").read_text() == before


def test_store_rejects_non_finite_results(tmp_path):
    st_ = store.ResultStore(tmp_path)
    with pytest.raises(ValueError):
        st_.put("k", labels={}, config={}, result={"v": float("inf")})
    assert len(st_) == 0


def test_diff_stores(tmp_path):
    a, b = store.ResultStore(tmp_path / "a"), store.ResultStore(tmp_path / "b")
    a.put("k0", **_fake_record(0))
    b.put("k0", **_fake_record(0))
    assert store.diff_stores(tmp_path / "a", tmp_path / "b") == []
    a.put("k1", **_fake_record(1))
    rec2 = _fake_record(2)
    rec2["result"] = {"value": -1.0}
    b.put("k2", **rec2)
    diffs = store.diff_stores(tmp_path / "a", tmp_path / "b")
    assert len(diffs) == 2 and any("k1" in d for d in diffs)
    # meta differences are NOT result differences
    recm = _fake_record(3)
    a.put("k3", **recm)
    recm["meta"] = {"wall_s": 999.0}
    b.put("k3", **recm)
    assert not any("k3" in d
                   for d in store.diff_stores(tmp_path / "a", tmp_path / "b"))


# ---------------------------------------------------------------------------
# runner: resume, chunking, bit-identity, parity
# ---------------------------------------------------------------------------

def test_resume_recomputes_zero_completed_cells(tmp_path):
    camp = _campaign()
    st_ = store.ResultStore(tmp_path)
    rep1 = runner.run_campaign(camp, st_, limit=3)
    assert (rep1.n_computed, rep1.n_skipped) == (3, 0)
    # fresh handle over the same directory — the interrupted-run picture
    rep2 = runner.run_campaign(camp, store.ResultStore(tmp_path))
    assert (rep2.n_computed, rep2.n_skipped) == (1, 3)
    rep3 = runner.run_campaign(camp, store.ResultStore(tmp_path))
    assert (rep3.n_computed, rep3.n_skipped) == (0, 4)
    # records come back in spec cell order regardless of compute order
    assert [r["labels"] for r in rep3.records] == \
        [c.label_dict for c in camp.cells]


def test_rerun_is_bit_identical_and_chunking_invisible(tmp_path):
    camp = _campaign()
    runner.run_campaign(camp, store.ResultStore(tmp_path / "fused"))
    # 1-lane chunks: every cell in its own dispatch
    rep = runner.run_campaign(camp, store.ResultStore(tmp_path / "lanes"),
                              chunk_budget_mb=1e-6)
    assert rep.n_chunks == 4
    assert store.diff_stores(tmp_path / "fused", tmp_path / "lanes") == []
    # interrupted-then-resumed store is byte-equal too
    st3 = store.ResultStore(tmp_path / "resumed")
    runner.run_campaign(camp, st3, limit=1)
    runner.run_campaign(camp, store.ResultStore(tmp_path / "resumed"))
    assert store.diff_stores(tmp_path / "fused", tmp_path / "resumed") == []


def test_campaign_matches_renewal_monte_carlo_scenarios():
    """The stacked heterogeneous dispatch reproduces the scenario-path
    engine bit-for-bit (CRN: gap sampling never sees the lane axis)."""
    from repro.core.scenarios import paper_scenarios
    camp = spec.campaign("parity", _axes()[0], base={
        **_base(),
        "process": {"kind": "exponential", "mtbf_s": MTBF_S}})
    recs = runner.run_campaign(camp).records
    cfgs = [paper_scenarios()[n] for n in (SCEN_A, SCEN_B)]
    direct = sweep.renewal_monte_carlo_scenarios(
        cfgs, jax.random.PRNGKey(0), n_runs=N_RUNS, makespan_s=MAKESPAN_S,
        mtbf_s=MTBF_S, max_failures=MAX_FAILURES)
    for rec, (name, summ) in zip(recs, direct.items()):
        expect = runner.summary_to_result(summ)
        got = {k: v for k, v in rec["result"].items()
               if k != "mean_makespan_s"}
        assert got == expect, f"campaign record diverges for {name}"


def test_topology_cell_key_resolves_and_changes_hash():
    base = {"scenario": {"base": SCEN_A},
            "process": {"kind": "exponential", "mtbf_s": MTBF_S},
            "run": {"n_runs": N_RUNS, "max_failures": MAX_FAILURES,
                    "makespan_s": MAKESPAN_S},
            "seed": 0}
    corr = dict(base, topology={"kind": "rack", "rack_size": 2,
                                "shock_mtbs_s": 5.0 * 24 * 3600.0,
                                "p_kill": 0.9})
    n_base = spec.normalize_config(base)
    n_corr = spec.normalize_config(corr)
    assert store.cell_key(n_base) != store.cell_key(n_corr)
    exp = spec.resolve(n_corr)
    assert exp.topology is not None
    assert spec.resolve(n_base).topology is None
    # unknown keys and kinds are rejected at normalize time
    with pytest.raises(ValueError, match="topology"):
        spec.normalize_config(dict(base, topology={"kind": "rack",
                                                   "rack_size": 2,
                                                   "shock_mtbs_s": 1.0,
                                                   "bogus": 1}))
    with pytest.raises(ValueError, match="kind"):
        spec.normalize_config(dict(base, topology={"kind": "mesh",
                                                   "rack_size": 2,
                                                   "shock_mtbs_s": 1.0}))


def test_correlated_campaign_matches_direct_dispatch():
    """A topology lane dispatches through the same fused engine as a
    direct ``renewal_monte_carlo`` call with that topology (CRN parity on
    the shared key), and iid lanes in the same campaign stay untouched."""
    from repro.core import topology as nt
    from repro.core.scenarios import paper_scenarios
    topo_spec = {"kind": "rack", "rack_size": 2,
                 "shock_mtbs_s": 5.0 * 24 * 3600.0, "p_kill": 0.9}
    m = spec.axis("topology", [("iid", {}),
                               ("rack", {"topology": topo_spec})])
    camp = spec.campaign("corr", m, base={
        "scenario": {"base": SCEN_A},
        "process": {"kind": "exponential", "mtbf_s": MTBF_S},
        "run": {"n_runs": N_RUNS, "max_failures": MAX_FAILURES,
                "makespan_s": MAKESPAN_S},
        "seed": 0})
    recs = {r["labels"]["topology"]: r for r in
            runner.run_campaign(camp).records}
    cfg = paper_scenarios()[SCEN_A]
    topo = nt.rack_topology(len(cfg.survivors) + 1, 2,
                            shock_mtbs_s=5.0 * 24 * 3600.0, p_kill=0.9)
    for label, topology in (("iid", None), ("rack", topo)):
        direct = sweep.renewal_monte_carlo(
            cfg, jax.random.PRNGKey(0), n_runs=N_RUNS,
            makespan_s=MAKESPAN_S, max_failures=MAX_FAILURES,
            process=__import__("repro.core.failures", fromlist=["x"])
            .Exponential(mtbf_s=MTBF_S), topology=topology)
        got = {k: v for k, v in recs[label]["result"].items()
               if k != "mean_makespan_s"}
        assert got == runner.summary_to_result(direct), label
    assert recs["rack"]["result"]["mean_failures"] !=         recs["iid"]["result"]["mean_failures"]


def test_seeded_chaos_cut_is_deterministic_and_in_range():
    from repro.campaign.__main__ import _seeded_cut
    for seed in (0, 1, 42, 123456789):
        n = _seeded_cut(seed, 12)
        assert _seeded_cut(seed, 12) == n     # both halves agree on it
        assert 1 <= n < 12
    # different seeds actually move the kill point
    assert len({_seeded_cut(s, 12) for s in range(40)}) > 3
    # degenerate matrix sizes stay in range
    assert _seeded_cut(7, 1) == 1
    assert _seeded_cut(7, 2) == 1


def test_chunk_lanes_memory_budget():
    camp = _campaign()
    exp = runner._RESOLVE_CACHE.get(
        store.cell_key(camp.cells[0].config)) or \
        spec.resolve(camp.cells[0].config)
    assert runner._chunk_lanes(100, exp, chunk_budget_mb=1e9) == 100
    assert runner._chunk_lanes(100, exp, chunk_budget_mb=1e-9) == 1
    per_lane = 2.0 * exp.n_runs * exp.max_failures * \
        (96 + 88 * (len(exp.cfg.survivors) + 1))
    assert runner._chunk_lanes(100, exp, per_lane * 3 / 1e6) == 3


def test_runner_names_offending_cell_on_bad_config():
    scen = spec.axis("scenario", [
        (SCEN_A, {"scenario": {"base": SCEN_A}})])
    camp = spec.campaign("bad", scen, base={
        **_base(), "policy": {"ckpt_interval": 1.0},
        "process": {"kind": "exponential", "mtbf_s": MTBF_S}})
    with pytest.raises(ValueError, match=f"scenario={SCEN_A}"):
        runner.run_campaign(camp)


# ---------------------------------------------------------------------------
# analyze
# ---------------------------------------------------------------------------

def test_analyze_verbs_and_tables(tmp_path):
    camp = _campaign()
    recs = runner.run_campaign(camp, store.ResultStore(tmp_path)).records
    assert len(analyze.select(recs, process="exp")) == 2
    grouped = analyze.group_by(recs, "scenario")
    assert set(grouped) == {SCEN_A, SCEN_B}
    v = analyze.get(recs[0], "result.mean_saving_j")
    assert isinstance(v, float)
    assert analyze.get(recs[0], "result.not_there", -1.0) == -1.0

    rows_lbl, cols_lbl, grid = analyze.pivot(
        recs, "scenario", "process", "result.mean_failures")
    assert rows_lbl == [SCEN_A, SCEN_B] and cols_lbl == ["exp", "wb07"]
    assert all(v is not None for row in grid for v in row)

    md = analyze.summary_table(
        recs, [("scenario", lambda r: analyze.label(r, "scenario")),
               ("E[fail]", ("result.mean_failures", ".1f"))])
    assert md.count("\n") == len(recs) + 1 and md.startswith("| scenario")
    txt = analyze.summary_table(recs, [("s", "labels.scenario")], fmt="text")
    assert "---" in txt.splitlines()[1]


def test_store_bench_rows_roundtrip(tmp_path):
    st_ = store.ResultStore(tmp_path)
    rows = [{"name": "campaign/cells_4", "us_per_call": 1.0,
             "decisions_per_s": 2.0, "derived": "x"}]
    st_.put_bench_rows(rows)
    assert store.ResultStore(tmp_path).bench_rows() == rows
    assert store.is_store(tmp_path)
    assert not store.is_store(tmp_path / "nope")
