"""Unit tests for the paper's energy model (eqs 1-15) against hand-derived
closed forms from Table 3 / §4.2 constants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy_model as em
from repro.core.characterization import (
    PowerTable,
    paper_machine_profile,
    paper_power_table,
    paper_sleep_spec,
)


@pytest.fixture
def ladder():
    return em.LadderArrays.from_table(paper_power_table())


@pytest.fixture
def sleep():
    return em.SleepArrays.from_spec(paper_sleep_spec())


def test_table3_values():
    pt = paper_power_table()
    assert pt.num_levels == 4
    np.testing.assert_allclose(pt.p_comp, [166, 148, 139, 126])
    np.testing.assert_allclose(pt.beta, [1.0, 1.2, 1.5, 2.1])
    np.testing.assert_allclose(pt.gamma, [1.0, 1.1, 1.2, 1.4])


def test_power_table_validation():
    with pytest.raises(ValueError):
        PowerTable(  # ascending frequencies
            freq_ghz=[1.2, 2.8], p_comp=[126, 166], beta=[1, 1],
            p_ckpt=[125, 150], gamma=[1, 1],
        )
    with pytest.raises(ValueError):
        PowerTable(  # beta[0] != 1
            freq_ghz=[2.8, 1.2], p_comp=[166, 126], beta=[1.1, 2.0],
            p_ckpt=[150, 125], gamma=[1, 1.4],
        )


def test_comp_time_and_energy(ladder):
    # 600 s of work + one 120 s checkpoint, per level
    ct = em.comp_time(600.0, 1.0, 120.0, ladder)
    np.testing.assert_allclose(
        ct, [600 + 120, 600 * 1.2 + 132, 600 * 1.5 + 144, 600 * 2.1 + 168], rtol=1e-6
    )
    ce = em.comp_energy(600.0, 1.0, 120.0, ladder)
    np.testing.assert_allclose(ce[0], 600 * 166 + 120 * 150, rtol=1e-6)
    np.testing.assert_allclose(ce[-1], 600 * 2.1 * 126 + 168 * 125, rtol=1e-6)


def test_sleep_transition_constants(sleep):
    # E_trans = 25*51 + 5*91 = 1730 J ; saving form: 154*W - 1370 (active ref)
    np.testing.assert_allclose(sleep.transition_energy, 1730.0)
    np.testing.assert_allclose(sleep.transition_time, 30.0)


@pytest.mark.parametrize("wait_s", [60.0, 229.9, 1920.0, 2040.0])
def test_sleep_saving_closed_form(ladder, sleep, wait_s):
    """Paper Table-4 identity: sleep saving over an active wait W is
    154*W - 1370 J for the Xeon/S3 characterization."""
    e_awake = wait_s * 166.0
    e_sleep = float(em.sleep_wait_energy(jnp.asarray(wait_s), sleep))
    np.testing.assert_allclose(e_awake - e_sleep, 154.0 * wait_s - 1370.0, rtol=1e-6)


def test_scenario2_reference_energy(ladder):
    """ENI of scenario 2 node 1: comp 481.2 s + ckpt 120 s + wait 1920 s,
    everything at fa with active waits => 416 599.2 J (Table 4: save
    294 294.6 J at 70.64% => ENI ~= 416 6xx)."""
    eni = em.reference_energy(
        481.2, 2521.2, 1.0, 120.0, ladder, em.WaitMode.ACTIVE, 60.0
    )
    np.testing.assert_allclose(float(eni), 481.2 * 166 + 120 * 150 + 1920 * 166, rtol=1e-6)


def test_intervention_energy_feasibility(ladder, sleep):
    """Scenario 1 node 1: 2.1 GHz comp would take ~21.6 min > T_failed
    (20.03 min) => infeasible (the paper prints 'Frequency not allowed')."""
    out = em.intervention_energy(
        972.0, 1202.0, 1.0, 120.0, ladder, sleep, em.WaitMode.ACTIVE, 60.0
    )
    feas = np.asarray(out["feasible"])
    assert feas[0]            # fa always feasible here
    assert not feas[1]        # 2.1 GHz: 972*1.2 + 132 = 1298.4 > 1202
    assert not feas[2] and not feas[3]
    assert np.isinf(np.asarray(out["total"])[1])


def test_idle_wait_power(ladder, sleep):
    """Idle waits draw the base power regardless of ladder level."""
    out = em.intervention_energy(
        100.0, 1000.0, 0.0, 120.0, ladder, sleep, em.WaitMode.IDLE, 60.0,
        mu1=1e9,  # forbid sleep
    )
    wait_t = np.asarray(out["wait_t"])
    np.testing.assert_allclose(np.asarray(out["e_wait"]), wait_t * 60.0, rtol=1e-6)


def test_t_failed_and_recover():
    np.testing.assert_allclose(
        float(em.t_failed_from_recovery(2040.0, 0.25, 1924.8)), 2040.0 + 481.2
    )
    np.testing.assert_allclose(float(em.t_recover(60.0, 60.0, 1920.0)), 2040.0)


def test_broadcasting_shapes(ladder, sleep):
    """(T, N) node grids broadcast against the (F,) ladder."""
    t_comp = jnp.ones((7, 3)) * 100.0
    t_failed = jnp.ones((7, 3)) * 500.0
    out = em.intervention_energy(
        t_comp, t_failed, jnp.zeros((7, 3)), 120.0, ladder, sleep,
        jnp.zeros((7, 3), jnp.int32), 60.0,
    )
    assert out["total"].shape == (7, 3, 4)
