"""Correlated-failure subsystem tests: topology shocks, trace ingestion,
engine threading, burst-hardened control.

Four layers, mirroring docs/failures.md's correlated section:

  * sampler statistics — with shocks effectively off the correlated
    sampler reproduces the declared iid law (KS at n = 50k), with shocks
    on the event stream is measurably over-dispersed;
  * cross-engine contract — fixed-key correlated histories are
    bit-identical host vs device, and the extended multi-felled event
    simulator cross-validates the device scan's epoch energies at
    <= 1e-4 relative on all six Table-4 scenarios (driven with an
    aggressive topology so multi-felled AND all-felled epochs are
    actually exercised);
  * trace ingestion — LANL-style CSV round-trip, burst detection,
    correlation-preserving replay, and shock-rate recovery from a
    synthetic log with known generating rates;
  * live stack — the injector replays kill sets as zero-gap bursts and
    the degrade-enabled controller holds a conservative policy through a
    burst storm (never worse than the static conservative baseline on
    realized ledger energy) while a naive always-retune controller is
    measurably worse.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointConfig
from repro.core import failures, simulator, sweep
from repro.core import topology as nt
from repro.core.scenarios import paper_scenarios
from repro.ft.controller import AdaptiveController, StochasticFailureInjector
from repro.ft.runtime import ClusterSpec, FTTrainer

KEY = jax.random.PRNGKey(3)
MTBF_S = 7 * 24 * 3600.0
MAKESPAN_S = 30 * 24 * 3600.0


# ---------------------------------------------------------------------------
# sampler statistics
# ---------------------------------------------------------------------------

def test_shock_off_marginals_match_declared_law():
    # with the shock clock pushed to an astronomic MTBS the correlated
    # sampler is the iid renewal model; for exponential marginals every
    # epoch gap is then Exp(mtbf / n) regardless of ages (memorylessness),
    # so one-sample KS at n = 50k against the analytic CDF applies
    n_nodes, n_runs, max_failures = 4, 2000, 25
    proc = failures.Exponential(mtbf_s=MTBF_S)
    topo = nt.rack_topology(n_nodes, 2, shock_mtbs_s=1e15, p_kill=1.0)
    gaps, fmask, primary = nt.correlated_renewal_gaps(
        topo, proc, KEY, n_runs=n_runs, n_nodes=n_nodes,
        max_failures=max_failures)
    assert int(np.sum(fmask.sum(-1) > 1)) == 0      # no shock ever fired
    g = np.asarray(gaps).ravel()
    assert g.size == 50_000
    scale = MTBF_S / n_nodes
    ks = failures.ks_statistic(g, lambda t: 1.0 - np.exp(-t / scale))
    assert ks < failures.ks_critical(g.size, alpha=1e-3)
    # primaries live on the node axis and match the mask
    assert np.all(fmask[np.arange(n_runs)[:, None],
                        np.arange(max_failures)[None, :], primary])


def test_dispersion_index_separates_shock_on_off():
    n_nodes = 8
    proc = failures.Exponential(mtbf_s=MTBF_S)

    def events(topo, key):
        gaps, fmask, _ = nt.correlated_renewal_gaps(
            topo, proc, key, n_runs=1, n_nodes=n_nodes, max_failures=4096)
        t = np.cumsum(np.asarray(gaps[0]))
        return np.repeat(t, np.asarray(fmask[0]).sum(-1))

    off = nt.rack_topology(n_nodes, 4, shock_mtbs_s=1e15, p_kill=1.0)
    on = nt.rack_topology(n_nodes, 4, shock_mtbs_s=5 * 24 * 3600.0,
                          p_kill=0.9)
    di_off = nt.dispersion_index(events(off, KEY))
    di_on = nt.dispersion_index(events(on, KEY))
    # superposed iid exponentials are Poisson-like (~1); shared shocks
    # over-disperse the counts
    assert 0.7 < di_off < 1.3
    assert di_on > di_off + 0.2
    assert di_on > 1.2


# ---------------------------------------------------------------------------
# cross-engine contract
# ---------------------------------------------------------------------------

def _aggressive_topology(n_nodes):
    # whole-machine shocks with high p_kill + age boosts: guarantees the
    # multi-felled AND all-felled branches are exercised, not just sampled
    # occasionally (a gentle topology leaves them untested)
    return nt.rack_topology(n_nodes, n_nodes, shock_mtbs_s=3 * 24 * 3600.0,
                            p_kill=0.95, age_boost_s=3600.0)


def test_correlated_histories_bit_identical_host_device():
    cfg = paper_scenarios()["scenario2_long_reexec"]
    n_nodes = len(cfg.survivors) + 1
    proc = failures.Weibull.from_mtbf(0.7, MTBF_S)
    topo = nt.rack_topology(n_nodes, 3, shock_mtbs_s=8 * 24 * 3600.0,
                            p_kill=0.6, age_boost_s=1800.0)
    g_h, pri_h, fm_h = sweep.renewal_failure_gaps(
        KEY, 32, n_nodes, 12, process=proc, topology=topo)
    res_d = sweep.renewal_monte_carlo_device(
        cfg, KEY, n_runs=32, max_failures=12, process=proc, topology=topo)
    np.testing.assert_array_equal(np.float32(g_h), np.asarray(res_d.gaps))
    valid = np.asarray(res_d.valid)
    np.testing.assert_array_equal(np.where(valid, pri_h, -1),
                                  np.asarray(res_d.failed_node))
    # shocks actually present in the fixture
    assert int(np.sum(fm_h.sum(-1) > 1)) > 0


def test_correlated_summaries_pinned_host_vs_device_all_scenarios():
    proc = failures.Weibull.from_mtbf(0.7, MTBF_S)
    for name, cfg in paper_scenarios().items():
        n_nodes = len(cfg.survivors) + 1
        topo = _aggressive_topology(n_nodes)
        kw = dict(n_runs=32, max_failures=12, process=proc, topology=topo)
        s_h = sweep.renewal_monte_carlo(cfg, KEY, engine="host", **kw)
        s_d = sweep.renewal_monte_carlo(cfg, KEY, **kw)
        assert s_d.per_node_failures == s_h.per_node_failures, name
        assert s_d.mean_failures == s_h.mean_failures, name
        for f in ("mean_energy_ref_j", "mean_energy_int_j", "mean_saving_j"):
            a, b = getattr(s_h, f), getattr(s_d, f)
            assert abs(a - b) <= 1e-4 * max(abs(a), 1.0), (name, f)


def test_simulator_cross_validates_multi_felled_epochs():
    proc = failures.Weibull.from_mtbf(0.7, MTBF_S)
    n_multi = n_all = 0
    for name, cfg in paper_scenarios().items():
        n_nodes = len(cfg.survivors) + 1
        n_surv = n_nodes - 1
        topo = _aggressive_topology(n_nodes)
        gaps, primary, fmask = sweep.renewal_failure_gaps(
            jax.random.PRNGKey(9), 4, n_nodes, 12, process=proc,
            topology=topo)
        felled = np.asarray(nt.survivor_slot_mask(fmask, primary))
        res = sweep.renewal_compose(cfg, gaps, MAKESPAN_S,
                                    failed_node=primary, felled=felled)
        for r in range(4):
            run = simulator.simulate_run(cfg, gaps[r], MAKESPAN_S,
                                         felled=felled[r])
            for e in run.epochs:
                k = e.index
                if e.felled is not None and e.felled.any():
                    n_multi += 1
                    n_all += int(e.felled.sum() == n_surv)
                for fld, oracle in (("energy_ref", res.epoch_ref),
                                    ("energy_int", res.epoch_int)):
                    a = getattr(e, fld)
                    b = np.asarray(oracle)[r, k]
                    rel = np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0))
                    assert rel < 1e-4, (name, r, k, fld)
                bf = float(np.asarray(res.epoch_failed)[r, k])
                assert abs(e.energy_failed - bf) <= 1e-4 * max(abs(bf), 1.0)
            for fld in ("energy_ref", "energy_int", "saving"):
                a = getattr(run, fld)
                b = float(np.asarray(getattr(res, fld))[r])
                assert abs(a - b) <= 1e-4 * max(abs(b), 1.0), (name, r, fld)
            assert run.n_failures == int(np.asarray(res.valid)[r].sum())
    # the whole point of the aggressive fixture: both shock branches ran
    assert n_multi > 10
    assert n_all > 0


def test_simulator_topology_sampling_path():
    cfg = paper_scenarios()["scenario2_long_reexec"]
    n_nodes = len(cfg.survivors) + 1
    proc = failures.Weibull.from_mtbf(0.7, MTBF_S)
    topo = _aggressive_topology(n_nodes)
    run = simulator.simulate_run(cfg, None, MAKESPAN_S, process=proc,
                                 key=KEY, topology=topo, max_failures=12)
    assert run.n_failures > 0
    with pytest.raises(ValueError):
        simulator.simulate_run(cfg, np.full(4, 1e5), MAKESPAN_S,
                               topology=topo)


# ---------------------------------------------------------------------------
# trace ingestion
# ---------------------------------------------------------------------------

def _synthetic_log(n_nodes=8, max_failures=400):
    proc = failures.Exponential(mtbf_s=MTBF_S)
    topo = nt.rack_topology(n_nodes, 2, shock_mtbs_s=10 * 24 * 3600.0,
                            p_kill=0.9)
    gaps, fmask, _ = nt.correlated_renewal_gaps(
        topo, proc, jax.random.PRNGKey(1), n_runs=1, n_nodes=n_nodes,
        max_failures=max_failures)
    return nt.history_to_log(gaps, fmask, downtime_s=600.0), topo


def test_lanl_csv_roundtrip_exact():
    log, _ = _synthetic_log()
    csv = nt.to_lanl_csv(log)
    log2 = nt.parse_lanl_csv(csv, n_nodes=8)
    np.testing.assert_array_equal(log.node, log2.node)
    np.testing.assert_allclose(log.t_s, log2.t_s, atol=1e-5)
    np.testing.assert_allclose(log.downtime_s, log2.downtime_s)


def test_fit_shock_rates_recovers_generating_rates():
    log, topo = _synthetic_log()
    fit = nt.fit_shock_rates(log, topo, burst_window_s=1.0)
    assert fit["rack"]["n_bursts"] > 10
    # attribution bias is real (spared-member shocks look individual), so
    # the tolerance is loose but the order of magnitude must be right
    assert abs(fit["rack"]["shock_mtbs_s"] / (10 * 24 * 3600.0) - 1.0) < 0.5
    assert abs(fit["individual"]["mtbf_s"] / MTBF_S - 1.0) < 0.35


def test_burst_replay_preserves_simultaneity():
    log, _ = _synthetic_log()
    gaps, mask, primary = nt.burst_replay_gaps(
        log, KEY, n_runs=4, max_failures=16, burst_window_s=1.0)
    assert gaps.shape == (4, 16) and mask.shape == (4, 16, 8)
    assert np.all(gaps > 0)
    assert np.all(mask[np.arange(4)[:, None], np.arange(16)[None, :],
                       primary])
    # the source log is bursty; the replay must keep multi-node epochs
    assert float(mask.sum(-1).mean()) > 1.05


def test_trace_to_empirical_marginals():
    log, _ = _synthetic_log()
    emp = nt.trace_to_empirical(log)
    assert isinstance(emp, failures.EmpiricalTrace)
    # a usable marginal process: mean in the same decade as the truth
    mean = float(np.mean(np.asarray(emp.mean_s())))
    assert 0.2 * MTBF_S < mean < 5.0 * MTBF_S


# ---------------------------------------------------------------------------
# live stack: injector bursts + controller degradation
# ---------------------------------------------------------------------------

N_PODS = 4
STEP_S = 100.0
DUR_S = 120.0
PROCESS = failures.Weibull.from_mtbf(0.7, 2000.0)


class TinyPipeline:
    def batch_at(self, step):
        return jnp.full((4,), float(step))


@jax.jit
def _tiny_step(params, opt_state, batch):
    g = jnp.mean(batch) * 0.01
    params = jax.tree.map(lambda p: p - 0.001 * (p + g), params)
    return params, opt_state, {"total_loss": jnp.mean(batch)}


def test_injector_replays_correlated_bursts():
    topo = nt.rack_topology(N_PODS, N_PODS, shock_mtbs_s=1500.0,
                            p_kill=0.9, age_boost_s=0.0)
    inj = StochasticFailureInjector(PROCESS, KEY, n_pods=N_PODS,
                                    max_failures=16, n_runs=2, run_index=1,
                                    topology=topo)
    gaps, primary, fmask = sweep.renewal_failure_gaps(
        KEY, 2, N_PODS, 16, process=PROCESS, topology=topo)
    # the flat queue is the epoch sequence with co-felled nodes expanded
    # as zero-gap entries right after their primary
    i = 0
    for k in range(16):
        assert inj.gaps[i] == gaps[1, k]
        assert inj.failed_node[i] == primary[1, k]
        i += 1
        for node in np.nonzero(fmask[1, k])[0]:
            if int(node) != int(primary[1, k]):
                assert inj.gaps[i] == 0.0
                assert inj.failed_node[i] == int(node)
                i += 1
    assert i == inj.gaps.shape[0]
    assert np.any(inj.gaps == 0.0)      # bursts present at this key


# handcrafted storm + moderate tail: three whole-cluster shock bursts in
# the first ~1000 s, then iid-looking ~900 s gaps for the rest of the run
STORM_GAPS = [600.0, 0.0, 0.0, 0.0, 200.0, 0.0, 0.0, 0.0,
              200.0, 0.0, 0.0, 0.0]
STORM_NODES = [0, 1, 2, 3] * 3
TAIL_GAPS = [800.0, 950.0, 900.0, 1000.0, 850.0, 900.0, 950.0, 800.0,
             1000.0, 900.0, 850.0, 950.0, 900.0, 800.0, 1000.0, 900.0,
             850.0, 950.0]
TAIL_NODES = [0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3, 0, 1]


def _storm_injector():
    inj = StochasticFailureInjector(PROCESS, KEY, n_pods=N_PODS,
                                    max_failures=32, n_runs=4, run_index=1)
    inj.gaps = np.asarray(STORM_GAPS + TAIL_GAPS, np.float64)
    inj.failed_node = np.asarray(STORM_NODES + TAIL_NODES, np.int64)
    return inj


def _trainer(root, *, controller=None, interval_steps=6):
    state = ({"w": jnp.ones((8,))}, {"m": jnp.zeros((8,))})
    return FTTrainer(
        step_fn=_tiny_step, pipeline=TinyPipeline(), state=state,
        cluster=ClusterSpec(n_pods=N_PODS, step_time_s=STEP_S),
        ckpt_cfg=CheckpointConfig(root=str(root),
                                  interval_steps=interval_steps, keep=3,
                                  phase_offset_steps=1),
        injector=_storm_injector(), ckpt_duration_s=DUR_S,
        controller=controller)


def _controller(degrade, hysteresis=99):
    return AdaptiveController(
        failures.Exponential(mtbf_s=2000.0), n_pods=N_PODS, retune_every=2,
        min_complete_gaps=3, cem_iters=2, cem_population=10, cem_n_runs=32,
        cem_max_failures=32, seed=0, degrade=degrade,
        conservative_policy={"ckpt_interval": 600.0},
        burst_window=2, near_zero_frac=0.25, hysteresis=hysteresis)


def test_degrade_controller_survives_burst_storm(tmp_path):
    """Acceptance: under an injected burst storm the degrade-enabled
    controller is never worse than the static conservative baseline on
    realized ledger energy, while a naive always-retune controller is
    measurably worse (it tunes on the poisoned window and carries the
    bad policy through the tail)."""
    n_steps = 200

    static = _trainer(tmp_path / "s")
    static.run(n_steps)
    static_j = static.energy.ledger_total_j()

    ctl_d = _controller(degrade=True)
    deg = _trainer(tmp_path / "d", controller=ctl_d)
    deg.run(n_steps)
    deg_j = deg.energy.ledger_total_j()

    ctl_n = _controller(degrade=False)
    naive = _trainer(tmp_path / "n", controller=ctl_n)
    naive.run(n_steps)
    naive_j = naive.energy.ledger_total_j()

    # the detector tripped and the controller refused to tune on the storm
    assert any(e["action"] == "degrade" for e in ctl_d.degrade_events)
    assert ctl_d.retunes == []
    assert deg.cluster.ckpt_interval_s == 600.0
    # PIT residuals collapse to ~0 on the zero-gap burst entries
    zero_resid = [u for g, u in zip(ctl_d._gap_log, ctl_d.pit) if g == 0.0]
    assert zero_resid and max(zero_resid) < 1e-6
    # the naive controller did keep refitting through the storm
    assert len(ctl_n.retunes) >= 5
    assert ctl_n.fitted is not None

    assert deg_j <= static_j
    assert naive_j > 1.03 * static_j
    assert naive_j > 1.03 * deg_j


def test_degrade_controller_reengages_after_calm():
    # prior stays in force (min_complete_gaps high), so with an exponential
    # prior the PIT residual is 1 - exp(-n·g/mtbf): zero gaps -> u ~ 0,
    # ~350 s gaps -> mid-range u that passes the uniform KS check
    ctl = AdaptiveController(
        failures.Exponential(mtbf_s=2000.0), n_pods=N_PODS, retune_every=4,
        min_complete_gaps=99, cem_iters=2, cem_population=10, cem_n_runs=32,
        cem_max_failures=32, seed=0, degrade=True,
        conservative_policy={"ckpt_interval": 600.0},
        burst_window=4, near_zero_frac=0.25, hysteresis=2)
    trainer = types.SimpleNamespace(
        cluster=ClusterSpec(n_pods=N_PODS, step_time_s=STEP_S),
        ckpt_duration_s=DUR_S)

    def fail(gap, pod, step):
        ctl.observe_failure(gap_s=gap, failed_pod=pod)
        return ctl.maybe_retune(trainer=trainer, remaining_work_s=1e5,
                                step=step)

    # storm: gate fires at failure 4 with window [300, 0, 0, 0] -> degrade
    for gap, pod in [(300.0, 0), (0.0, 1), (0.0, 2)]:
        assert fail(gap, pod, 1) is None
    pol = fail(0.0, 3, 4)
    assert ctl.degraded
    assert pol == {"ckpt_interval": 600.0}
    assert ctl.retunes == []            # no refit on the poisoned window
    # one more burst straggler, then calm gaps; the failure-8 window
    # [0, 400, 300, 500] still holds a zero -> still degraded
    seq = [(0.0, 0), (400.0, 1), (300.0, 2), (500.0, 3),
           (350.0, 0), (420.0, 1), (380.0, 2), (450.0, 3)]
    for gap, pod in seq[:4]:
        assert fail(gap, pod, 8) is None
    assert ctl.degraded
    # failure 12: all-calm window -> first calm check only arms hysteresis
    for gap, pod in seq[4:]:
        pol = fail(gap, pod, 12)
    assert pol is None and ctl.degraded
    # failure 16: second calm check -> re-engage and actually retune
    for gap, pod in [(390.0, 0), (410.0, 1), (360.0, 2)]:
        fail(gap, pod, 15)
    pol = fail(430.0, 3, 16)
    assert not ctl.degraded
    assert [e["action"] for e in ctl.degrade_events] == \
        ["degrade", "re-engage"]
    assert pol is not None and ctl.retunes
