"""Serving-layer tests: the shape-bucket batching primitives and the
decode-serving CLI that consumes them.

``launch.batching`` owns the pad/scatter bookkeeping for BOTH serving
drivers (token decode and the fleet policy advisor), so its contract is
pinned here once: bucket selection (including the sharded multiple-of
constraint and the beyond-largest-bucket fallback), edge-padding for
arrays and lists, group/scatter as exact inverses on any request stream,
and the refusal paths (empty batches, oversized batches, results that
still carry padding).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.launch.batching import (
    DEFAULT_BUCKETS,
    bucket_size,
    group_indices,
    pad_rows,
    scatter,
)


# ---------------------------------------------------------------------------
# bucket_size
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,expect", [
    (1, 1), (2, 2), (3, 4), (5, 8), (8, 8), (9, 16), (100, 128),
    (1000, 1024), (1024, 1024),
])
def test_bucket_size_default_buckets(n, expect):
    assert bucket_size(n) == expect


def test_bucket_size_multiple_of_skips_indivisible_buckets():
    # 3 requests over 2 shards: bucket 4 is the smallest divisible fit
    assert bucket_size(3, multiple_of=2) == 4
    assert bucket_size(1, multiple_of=2) == 2
    # a 3-way shard skips every power of two beyond... all of them: the
    # fallback produces the next exact multiple instead of erroring
    assert bucket_size(5, buckets=(4, 8), multiple_of=3) == 6


def test_bucket_size_overflow_falls_back_to_exact_multiple():
    assert bucket_size(2000) == 2000
    assert bucket_size(2001, multiple_of=2) == 2002


def test_bucket_size_unsorted_buckets():
    assert bucket_size(3, buckets=(16, 4, 8)) == 4


def test_bucket_size_rejects_nonpositive():
    with pytest.raises(ValueError, match="batch size"):
        bucket_size(0)
    with pytest.raises(ValueError, match="multiple_of"):
        bucket_size(4, multiple_of=0)


# ---------------------------------------------------------------------------
# pad_rows
# ---------------------------------------------------------------------------

def test_pad_rows_array_repeats_last_row():
    rows = np.arange(6).reshape(3, 2)
    out = pad_rows(rows, 5)
    assert out.shape == (5, 2)
    np.testing.assert_array_equal(out[:3], rows)
    np.testing.assert_array_equal(out[3], rows[-1])
    np.testing.assert_array_equal(out[4], rows[-1])


def test_pad_rows_list_and_noop():
    assert pad_rows(["a", "b"], 4) == ["a", "b", "b", "b"]
    rows = np.ones((4, 2))
    assert pad_rows(rows, 4) is rows        # exact fit: untouched
    lst = ["x"]
    assert pad_rows(lst, 1) is lst


def test_pad_rows_refusals():
    with pytest.raises(ValueError, match="empty"):
        pad_rows([], 4)
    with pytest.raises(ValueError, match="does not fit"):
        pad_rows([1, 2, 3], 2)


# ---------------------------------------------------------------------------
# group_indices / scatter: exact inverses
# ---------------------------------------------------------------------------

def test_group_indices_preserves_order():
    groups = group_indices(["b", "a", "b", "c", "a"])
    assert list(groups) == ["b", "a", "c"]          # first-seen group order
    assert groups == {"b": [0, 2], "a": [1, 4], "c": [3]}


def test_scatter_round_trip():
    keys = ["b", "a", "b", "c", "a", "b"]
    groups = group_indices(keys)
    # each group answers its own requests in within-group order
    results = {k: [f"{k}{j}" for j in range(len(idx))]
               for k, idx in groups.items()}
    out = scatter(groups, results)
    assert out == ["b0", "a0", "b1", "c0", "a1", "b2"]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from("abcd"), min_size=1, max_size=32))
def test_scatter_inverts_group_indices(keys):
    """Property: scattering each request's own index through the group
    round trip reproduces the identity permutation for ANY stream."""
    groups = group_indices(keys)
    results = {k: list(idx) for k, idx in groups.items()}
    assert scatter(groups, results) == list(range(len(keys)))


def test_scatter_rejects_padded_results():
    groups = group_indices(["a", "a"])
    with pytest.raises(ValueError, match="sliced off"):
        scatter(groups, {"a": [1, 2, 3]})       # padding leaked through


def test_scatter_empty_stream():
    assert scatter({}, {}) == []


# ---------------------------------------------------------------------------
# the decode-serving CLI rides the same helpers
# ---------------------------------------------------------------------------

def test_serve_cli_pads_to_bucket_and_slices_back(monkeypatch, capsys):
    """End-to-end: a 3-prompt batch is served through the 4-wide bucket
    and reports exactly 3 rows of real tokens."""
    from repro.launch import serve

    monkeypatch.setattr("sys.argv", [
        "serve", "--arch", "mamba2-370m", "--batch", "3",
        "--prompt-len", "4", "--gen", "4"])
    serve.main()
    out = capsys.readouterr().out
    assert "(bucket 4)" in out
    assert "3x4 tokens" in out
